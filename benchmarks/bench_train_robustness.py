"""Training-robustness chaos replay: seeded faults + invariant audit +
fault-free bit parity (ISSUE 8 acceptance).

Three arms over the SAME tiny-LM train step (jnp chain — the CPU CI
backend; the fused kernel carries the identical SC_OK gate and is
covered by the tier-1 tests):

1. **plain** — ``run_loop`` with no checkpointing and no monitor: the
   ground-truth trajectory.
2. **fault-free chaos** — the full chaos harness (``run_chaos`` with
   ``plan=None``): auto-resume on, spike monitor armed, checkpoints
   written, poison scalar stamped 1.0 on every batch.  Its final
   ``params/opt/step`` must be BIT-IDENTICAL to the plain arm — the
   self-healing machinery is free when nothing goes wrong (multiply by
   1.0 and ``where(True, new, old)`` are IEEE identities).
3. **seeded chaos** — a :func:`repro.train.faults.chaos_train_plan`
   exercising every recovery tier: NaN/inf batches (skip), a sustained
   finite loss blow-up (spike rollback + LR backoff), hard kills after
   the step and mid-checkpoint-write (auto-resume), a bit-flipped
   published payload (quarantine).  The per-step
   :class:`~repro.train.faults.TrainAuditor` must report ZERO
   violations and the run must complete with a finite loss.

All columns are deterministic on a fixed backend (seeded plan, seeded
data, ``prefetch=0``), so ``check_regression.py`` gates them at zero
tolerance; the invariant/parity columns are the acceptance bar itself.

Emits ``BENCH_train.json`` (``--json-dir DIR``); ``--tiny`` is the CI
smoke (1-layer model, 18 steps) and is what the committed baseline was
generated from.
"""

from __future__ import annotations

import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import QuantConfig, QuantPolicy
from repro.data import DataPipeline, lm_batch, permutation_table
from repro.models.lm import LMConfig, lm_init
from repro.optim import adamw, constant
from repro.train import (TrainConfig, init_state, make_optimizer,
                         make_train_step)
from repro.train import faults as tfaults
from repro.train.loop import run_loop

from .common import emit, write_bench_json

# plan parameters verified (per mode) to exercise every recovery tier:
# >=1 skip, >=1 rollback, >=1 mid-write kill, >=1 quarantine, zero audit
# violations (see the committed baseline counters).  The quarantine tier
# needs the corrupted save to still be the newest candidate at some
# restore, so the corrupt ordinal shifts with the run length.
PLAN_TINY = dict(seed=1, spike_at=24, spike_len=3, n_crashes=1,
                 ckpt_crash_save=2, ckpt_crash_stage="manifest",
                 corrupt_save=3, corrupt_mode="bitflip")
PLAN_FULL = dict(seed=3, spike_at=24, spike_len=3, n_crashes=1,
                 ckpt_crash_save=2, ckpt_crash_stage="manifest",
                 corrupt_save=5, corrupt_mode="bitflip")
SPIKE_WARMUP = 4
CKPT_EVERY = 3


def _setup(tiny: bool):
    if tiny:
        cfg = LMConfig(name="tiny", n_layers=1, d_model=32, n_heads=2,
                       n_kv_heads=2, d_ff=64, vocab=32,
                       dtype=jnp.float32, remat=False)
        n_steps, b, l = 18, 4, 16
    else:
        cfg = LMConfig(name="small", n_layers=2, d_model=64, n_heads=4,
                       n_kv_heads=2, d_ff=128, vocab=64,
                       dtype=jnp.float32, remat=False)
        n_steps, b, l = 36, 8, 32
    tcfg = TrainConfig(
        quant=QuantConfig(method="lotion", fmt_name="int4", lam=1e3,
                          policy=QuantPolicy(min_size=64),
                          use_kernel=False),
        clip_norm=1.0, n_microbatches=1, seed=0)
    perm = permutation_table(0, cfg.vocab)

    def batch_fn(step):
        return lm_batch(0, step, b, l, cfg.vocab, perm)

    opt = make_optimizer(tcfg, adamw(constant(1e-2)))

    def make_state():
        params = lm_init(jax.random.PRNGKey(0), cfg)
        return init_state(params, opt, lr_scale=True)

    step = make_train_step(cfg, tcfg, opt,
                           loss_fn=tfaults.chaos_loss_fn(cfg, tcfg))
    plan_args = dict(PLAN_TINY if tiny else PLAN_FULL)
    config = {"arch": {"n_layers": cfg.n_layers, "d_model": cfg.d_model,
                       "n_heads": cfg.n_heads, "vocab": cfg.vocab},
              "n_steps": n_steps, "batch": b, "seq": l,
              "plan": plan_args, "spike_warmup": SPIKE_WARMUP,
              "ckpt_every": CKPT_EVERY}
    return step, make_state, batch_fn, n_steps, plan_args, config


def _plain_run(step, make_state, batch_fn, n_steps):
    """Ground-truth trajectory: no checkpoints, no monitor, poison=1.0."""

    def fn(s):
        b = dict(batch_fn(s))
        b["poison"] = np.asarray(1.0, np.float32)
        return b

    pipe = DataPipeline(fn, prefetch=0)
    out = run_loop(step, make_state(), pipe, n_steps, log_every=0,
                   log=lambda *a, **k: None)
    pipe.close()
    return out["state"]


def _bit_parity(a, b) -> bool:
    """Bitwise equality of the params/opt/step slices of two states
    (``lr_scale`` and other driver-owned scalars are excluded — the
    plain arm never touches them)."""
    pa = {k: a[k] for k in ("params", "opt", "step")}
    pb = {k: b[k] for k in ("params", "opt", "step")}
    if (jax.tree_util.tree_structure(pa) != jax.tree_util.tree_structure(pb)):
        return False
    la = jax.tree_util.tree_leaves(pa)
    lb = jax.tree_util.tree_leaves(pb)
    return all(np.asarray(x).tobytes() == np.asarray(y).tobytes()
               for x, y in zip(la, lb))


def robustness(step, make_state, batch_fn, n_steps, plan_args) -> dict:
    plain = _plain_run(step, make_state, batch_fn, n_steps)

    with tempfile.TemporaryDirectory(prefix="bench_ff_") as d:
        ff = tfaults.run_chaos(step, make_state, batch_fn, None, n_steps, d,
                               ckpt_every=CKPT_EVERY,
                               spike_warmup=SPIKE_WARMUP)
    parity = ff["state"] is not None and _bit_parity(plain, ff["state"])

    plan = tfaults.chaos_train_plan(n_steps=n_steps, **plan_args)
    with tempfile.TemporaryDirectory(prefix="bench_chaos_") as d:
        ch = tfaults.run_chaos(step, make_state, batch_fn, plan, n_steps, d,
                               ckpt_every=CKPT_EVERY,
                               spike_warmup=SPIKE_WARMUP)

    return {
        "plan": plan.describe(),
        "invariant_violations": len(ch["violations"]),
        "violations": ch["violations"],
        "fault_free_violations": len(ff["violations"]),
        "fault_free_bit_parity": bool(parity),
        "chaos_completed": ch["result"] is not None,
        "final_loss_finite": bool(np.isfinite(ch["final_loss"])),
        "final_loss": float(ch["final_loss"]),
        "segments": ch["segments"],
        "crashes": ch["crashes"],
        "resumes": ch["resumes"],
        "rollbacks": ch["rollbacks"],
        "skipped_steps": ch["skipped"],
        "replayed_steps": ch["replayed_steps"],
        "steps_seen": ch["steps_seen"],
        "saves": ch["saves"],
        "corrupted_saves": ch["corrupted_saves"],
        "quarantined": ch["quarantined"],
    }


def main(fast: bool = False, tiny: bool = False, json_dir: str = None):
    step, make_state, batch_fn, n_steps, plan_args, config = _setup(
        tiny or fast)
    rob = robustness(step, make_state, batch_fn, n_steps, plan_args)
    rec = {
        "bench": "train_robustness",
        "backend": jax.default_backend(),
        "config": config,
        "robustness": rob,
        "note": ("all counters are deterministic (seeded plan + seeded "
                 "data + prefetch=0): check_regression.py gates them at "
                 "zero tolerance; violations/parity are the acceptance "
                 "bar itself"),
    }
    emit("train_chaos_violations", 0.0, f"n={rob['invariant_violations']}")
    emit("train_chaos_recovery", 0.0,
         f"skips={rob['skipped_steps']} rollbacks={rob['rollbacks']} "
         f"resumes={rob['resumes']} quarantined={rob['quarantined']}")
    emit("train_fault_free_parity", 0.0,
         f"bit_identical={rob['fault_free_bit_parity']}")

    # the acceptance bar holds regardless of baselines
    assert rob["invariant_violations"] == 0, rob["violations"]
    assert rob["fault_free_violations"] == 0
    assert rob["fault_free_bit_parity"], \
        "fault-free chaos replay diverged from the plain run"
    assert rob["chaos_completed"] and rob["final_loss_finite"]
    # the plan must actually exercise every recovery tier
    for tier in ("skipped_steps", "rollbacks", "resumes", "quarantined"):
        assert rob[tier] >= 1, f"chaos plan exercised no {tier}"

    if json_dir is not None:
        print(f"wrote {write_bench_json('train', rec, json_dir)}")
    return rec


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: 1-layer model, 18 chaos steps")
    ap.add_argument("--json-dir", default=None,
                    help="write BENCH_train.json into this directory")
    a = ap.parse_args()
    main(fast=a.fast, tiny=a.tiny, json_dir=a.json_dir)
