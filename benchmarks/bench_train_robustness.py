"""Training-robustness chaos replay: seeded faults + invariant audit +
fault-free bit parity (ISSUE 8 acceptance).

Three arms over the SAME tiny-LM train step (jnp chain — the CPU CI
backend; the fused kernel carries the identical SC_OK gate and is
covered by the tier-1 tests):

1. **plain** — ``run_loop`` with no checkpointing and no monitor: the
   ground-truth trajectory.
2. **fault-free chaos** — the full chaos harness (``run_chaos`` with
   ``plan=None``): auto-resume on, spike monitor armed, checkpoints
   written, poison scalar stamped 1.0 on every batch.  Its final
   ``params/opt/step`` must be BIT-IDENTICAL to the plain arm — the
   self-healing machinery is free when nothing goes wrong (multiply by
   1.0 and ``where(True, new, old)`` are IEEE identities).
3. **seeded chaos** — a :func:`repro.train.faults.chaos_train_plan`
   exercising every recovery tier: NaN/inf batches (skip), a sustained
   finite loss blow-up (spike rollback + LR backoff), hard kills after
   the step and mid-checkpoint-write (auto-resume), a bit-flipped
   published payload (quarantine).  The per-step
   :class:`~repro.train.faults.TrainAuditor` must report ZERO
   violations and the run must complete with a finite loss.

All columns are deterministic on a fixed backend (seeded plan, seeded
data, ``prefetch=0``), so ``check_regression.py`` gates them at zero
tolerance; the invariant/parity columns are the acceptance bar itself.

Emits ``BENCH_train.json`` (``--json-dir DIR``); ``--tiny`` is the CI
smoke (1-layer model, 18 steps) and is what the committed baseline was
generated from.
"""

from __future__ import annotations

import argparse
import os
import tempfile

# the distributed section needs forced host devices, and XLA reads the
# flag only before backend init — so the env dance happens at module
# top, before jax is imported.  Local runs therefore see the same
# 8-device backend CI generates the committed baselines on.
os.environ.setdefault("REPRO_FORCE_DEVICES", "8")
if ("--xla_force_host_platform_device_count"
        not in os.environ.get("XLA_FLAGS", "")):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count="
        f"{os.environ['REPRO_FORCE_DEVICES']} "
        + os.environ.get("XLA_FLAGS", ""))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import QuantConfig, QuantPolicy
from repro.data import DataPipeline, lm_batch, permutation_table
from repro.models.lm import LMConfig, lm_init
from repro.optim import adamw, constant
from repro.train import (TrainConfig, init_state, make_optimizer,
                         make_train_step)
from repro.train import faults as tfaults
from repro.train.loop import run_loop

from .common import emit, write_bench_json

# plan parameters verified (per mode) to exercise every recovery tier:
# >=1 skip, >=1 rollback, >=1 mid-write kill, >=1 quarantine, zero audit
# violations (see the committed baseline counters).  The quarantine tier
# needs the corrupted save to still be the newest candidate at some
# restore, so the corrupt ordinal shifts with the run length.
PLAN_TINY = dict(seed=1, spike_at=24, spike_len=3, n_crashes=1,
                 ckpt_crash_save=2, ckpt_crash_stage="manifest",
                 corrupt_save=3, corrupt_mode="bitflip")
PLAN_FULL = dict(seed=3, spike_at=24, spike_len=3, n_crashes=1,
                 ckpt_crash_save=2, ckpt_crash_stage="manifest",
                 corrupt_save=5, corrupt_mode="bitflip")
SPIKE_WARMUP = 4
CKPT_EVERY = 3

# host-level chaos plan for the distributed arm (DESIGN.md §12, ISSUE 9
# acceptance): one peer-host kill, one straggler past the deadline, one
# shard-targeted bitflip and one torn manifest on top of the data-level
# tiers.  Ordinals verified (like PLAN_TINY's) to make every tier
# actually fire on the deterministic CPU testbed.
DIST_MESH = (2, 4)                       # data x model -> n_hosts = 2
HOST_PLAN_TINY = dict(seed=1, spike_at=24, spike_len=3, n_crashes=1,
                      ckpt_crash_save=2, ckpt_crash_stage="manifest",
                      corrupt_save=3, corrupt_mode=("bitflip", 1),
                      torn_manifest_save=4,
                      n_hosts=2, host_kill_at=2, straggle_at=7)
HOST_PLAN_FULL = dict(seed=3, spike_at=24, spike_len=3, n_crashes=1,
                      ckpt_crash_save=2, ckpt_crash_stage="manifest",
                      corrupt_save=5, corrupt_mode=("bitflip", 1),
                      torn_manifest_save=6,
                      n_hosts=2, host_kill_at=2, straggle_at=7)


def _setup(tiny: bool):
    if tiny:
        cfg = LMConfig(name="tiny", n_layers=1, d_model=32, n_heads=2,
                       n_kv_heads=2, d_ff=64, vocab=32,
                       dtype=jnp.float32, remat=False)
        n_steps, b, l = 18, 4, 16
    else:
        cfg = LMConfig(name="small", n_layers=2, d_model=64, n_heads=4,
                       n_kv_heads=2, d_ff=128, vocab=64,
                       dtype=jnp.float32, remat=False)
        n_steps, b, l = 36, 8, 32
    tcfg = TrainConfig(
        quant=QuantConfig(method="lotion", fmt_name="int4", lam=1e3,
                          policy=QuantPolicy(min_size=64),
                          use_kernel=False),
        clip_norm=1.0, n_microbatches=1, seed=0)
    perm = permutation_table(0, cfg.vocab)

    def batch_fn(step):
        return lm_batch(0, step, b, l, cfg.vocab, perm)

    opt = make_optimizer(tcfg, adamw(constant(1e-2)))

    def make_state():
        params = lm_init(jax.random.PRNGKey(0), cfg)
        return init_state(params, opt, lr_scale=True)

    step = make_train_step(cfg, tcfg, opt,
                           loss_fn=tfaults.chaos_loss_fn(cfg, tcfg))
    plan_args = dict(PLAN_TINY if tiny else PLAN_FULL)
    host_plan_args = dict(HOST_PLAN_TINY if tiny else HOST_PLAN_FULL)
    config = {"arch": {"n_layers": cfg.n_layers, "d_model": cfg.d_model,
                       "n_heads": cfg.n_heads, "vocab": cfg.vocab},
              "n_steps": n_steps, "batch": b, "seq": l,
              "plan": plan_args, "spike_warmup": SPIKE_WARMUP,
              "ckpt_every": CKPT_EVERY,
              "dist_mesh": list(DIST_MESH),
              "host_plan": {k: list(v) if isinstance(v, tuple) else v
                            for k, v in host_plan_args.items()}}
    return step, make_state, batch_fn, n_steps, plan_args, \
        host_plan_args, config


def _plain_run(step, make_state, batch_fn, n_steps):
    """Ground-truth trajectory: no checkpoints, no monitor, poison=1.0."""

    def fn(s):
        b = dict(batch_fn(s))
        b["poison"] = np.asarray(1.0, np.float32)
        return b

    pipe = DataPipeline(fn, prefetch=0)
    out = run_loop(step, make_state(), pipe, n_steps, log_every=0,
                   log=lambda *a, **k: None)
    pipe.close()
    return out["state"]


def _bit_parity(a, b) -> bool:
    """Bitwise equality of the params/opt/step slices of two states
    (``lr_scale`` and other driver-owned scalars are excluded — the
    plain arm never touches them)."""
    pa = {k: a[k] for k in ("params", "opt", "step")}
    pb = {k: b[k] for k in ("params", "opt", "step")}
    if (jax.tree_util.tree_structure(pa) != jax.tree_util.tree_structure(pb)):
        return False
    la = jax.tree_util.tree_leaves(pa)
    lb = jax.tree_util.tree_leaves(pb)
    return all(np.asarray(x).tobytes() == np.asarray(y).tobytes()
               for x, y in zip(la, lb))


def robustness(step, make_state, batch_fn, n_steps, plan_args) -> dict:
    plain = _plain_run(step, make_state, batch_fn, n_steps)

    with tempfile.TemporaryDirectory(prefix="bench_ff_") as d:
        ff = tfaults.run_chaos(step, make_state, batch_fn, None, n_steps, d,
                               ckpt_every=CKPT_EVERY,
                               spike_warmup=SPIKE_WARMUP)
    parity = ff["state"] is not None and _bit_parity(plain, ff["state"])

    plan = tfaults.chaos_train_plan(n_steps=n_steps, **plan_args)
    with tempfile.TemporaryDirectory(prefix="bench_chaos_") as d:
        ch = tfaults.run_chaos(step, make_state, batch_fn, plan, n_steps, d,
                               ckpt_every=CKPT_EVERY,
                               spike_warmup=SPIKE_WARMUP)

    return {
        "plan": plan.describe(),
        "invariant_violations": len(ch["violations"]),
        "violations": ch["violations"],
        "fault_free_violations": len(ff["violations"]),
        "fault_free_bit_parity": bool(parity),
        "chaos_completed": ch["result"] is not None,
        "final_loss_finite": bool(np.isfinite(ch["final_loss"])),
        "final_loss": float(ch["final_loss"]),
        "segments": ch["segments"],
        "crashes": ch["crashes"],
        "resumes": ch["resumes"],
        "rollbacks": ch["rollbacks"],
        "skipped_steps": ch["skipped"],
        "replayed_steps": ch["replayed_steps"],
        "steps_seen": ch["steps_seen"],
        "saves": ch["saves"],
        "corrupted_saves": ch["corrupted_saves"],
        "quarantined": ch["quarantined"],
    }


def distributed(step, make_state, batch_fn, n_steps,
                host_plan_args) -> dict:
    """The 2x4-mesh arm (DESIGN.md §12): plain mesh run, fault-free
    chaos bit parity on the SAME mesh, then the seeded host-level plan —
    peer-host kill, straggler, torn manifest, one corrupted shard of a
    2-shard save — with the cross-host fingerprint + replica audit on
    every step and zero tolerance for violations."""
    n_hosts = host_plan_args["n_hosts"]
    mesh = jax.make_mesh(DIST_MESH, ("data", "model"))
    rep = NamedSharding(mesh, P())

    def make_state_mesh():
        return jax.tree.map(lambda x: jax.device_put(x, rep), make_state())

    probe = batch_fn(0)
    batch_sh = {k: NamedSharding(
        mesh, P(*(("data",) + (None,) * (np.asarray(v).ndim - 1))))
        for k, v in probe.items()}
    batch_sh["poison"] = rep             # injector-stamped scalar

    with mesh:
        def fn(s):
            b = dict(batch_fn(s))
            b["poison"] = np.asarray(1.0, np.float32)
            return b

        pipe = DataPipeline(fn, prefetch=0, sharding=batch_sh)
        plain = run_loop(step, make_state_mesh(), pipe, n_steps,
                         log_every=0, log=lambda *a, **k: None)["state"]
        pipe.close()

        with tempfile.TemporaryDirectory(prefix="bench_dist_ff_") as d:
            ff = tfaults.run_chaos(step, make_state_mesh, batch_fn, None,
                                   n_steps, d, ckpt_every=CKPT_EVERY,
                                   spike_warmup=SPIKE_WARMUP,
                                   n_hosts=n_hosts, ckpt_shards=n_hosts,
                                   batch_sharding=batch_sh)
        parity = ff["state"] is not None and _bit_parity(plain, ff["state"])

        plan = tfaults.chaos_train_plan(n_steps=n_steps, **host_plan_args)
        with tempfile.TemporaryDirectory(prefix="bench_dist_chaos_") as d:
            ch = tfaults.run_chaos(step, make_state_mesh, batch_fn, plan,
                                   n_steps, d, ckpt_every=CKPT_EVERY,
                                   spike_warmup=SPIKE_WARMUP,
                                   n_hosts=n_hosts, ckpt_shards=n_hosts,
                                   batch_sharding=batch_sh)

    return {
        "mesh": f"{DIST_MESH[0]}x{DIST_MESH[1]}",
        "n_hosts": n_hosts,
        "devices": int(mesh.size),
        "plan": plan.describe(),
        "invariant_violations": len(ch["violations"]),
        "violations": ch["violations"],
        "fault_free_violations": len(ff["violations"]),
        "fault_free_bit_parity": bool(parity),
        "chaos_completed": ch["result"] is not None,
        "final_loss_finite": bool(np.isfinite(ch["final_loss"])),
        "final_loss": float(ch["final_loss"]),
        "segments": ch["segments"],
        "crashes": ch["crashes"],
        "resumes": ch["resumes"],
        "rollbacks": ch["rollbacks"],
        "skipped_steps": ch["skipped"],
        "saves": ch["saves"],
        "quarantined": ch["quarantined"],
        "host_kills": ch["host_kills"],
        "straggles": ch["straggles"],
        "host_kill_timeouts": ch["host_kill_timeouts"],
        "straggler_timeouts": ch["straggler_timeouts"],
        "divergence_checks": ch["divergence_checks"],
        "data_windows_skipped": ch["data_windows_skipped"],
    }


def main(fast: bool = False, tiny: bool = False, json_dir: str = None):
    step, make_state, batch_fn, n_steps, plan_args, host_plan_args, \
        config = _setup(tiny or fast)
    rob = robustness(step, make_state, batch_fn, n_steps, plan_args)
    dist = distributed(step, make_state, batch_fn, n_steps,
                       host_plan_args)
    rec = {
        "bench": "train_robustness",
        "backend": jax.default_backend(),
        "config": config,
        "robustness": rob,
        "distributed": dist,
        "note": ("all counters are deterministic (seeded plan + seeded "
                 "data + prefetch=0 + virtual coordinator clock): "
                 "check_regression.py gates them at zero tolerance; "
                 "violations/parity are the acceptance bar itself"),
    }
    emit("train_chaos_violations", 0.0, f"n={rob['invariant_violations']}")
    emit("train_chaos_recovery", 0.0,
         f"skips={rob['skipped_steps']} rollbacks={rob['rollbacks']} "
         f"resumes={rob['resumes']} quarantined={rob['quarantined']}")
    emit("train_fault_free_parity", 0.0,
         f"bit_identical={rob['fault_free_bit_parity']}")
    emit("train_dist_chaos", 0.0,
         f"mesh={dist['mesh']} host_kills={dist['host_kill_timeouts']} "
         f"stragglers={dist['straggler_timeouts']} "
         f"quarantined={dist['quarantined']} "
         f"rollbacks={dist['rollbacks']} "
         f"violations={dist['invariant_violations']}")
    emit("train_dist_parity", 0.0,
         f"bit_identical={dist['fault_free_bit_parity']} "
         f"divergence_checks={dist['divergence_checks']}")

    # the acceptance bar holds regardless of baselines
    assert rob["invariant_violations"] == 0, rob["violations"]
    assert rob["fault_free_violations"] == 0
    assert rob["fault_free_bit_parity"], \
        "fault-free chaos replay diverged from the plain run"
    assert rob["chaos_completed"] and rob["final_loss_finite"]
    # the plan must actually exercise every recovery tier
    for tier in ("skipped_steps", "rollbacks", "resumes", "quarantined"):
        assert rob[tier] >= 1, f"chaos plan exercised no {tier}"
    # the distributed acceptance bar (ISSUE 9): zero violations, mesh
    # bit parity, and every host-level tier actually fired
    assert dist["invariant_violations"] == 0, dist["violations"]
    assert dist["fault_free_violations"] == 0
    assert dist["fault_free_bit_parity"], \
        "fault-free mesh chaos replay diverged from the plain 2x4 run"
    assert dist["chaos_completed"] and dist["final_loss_finite"]
    for tier in ("host_kill_timeouts", "straggler_timeouts",
                 "quarantined", "rollbacks", "divergence_checks"):
        assert dist[tier] >= 1, f"distributed chaos exercised no {tier}"

    if json_dir is not None:
        print(f"wrote {write_bench_json('train', rec, json_dir)}")
    return rec


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: 1-layer model, 18 chaos steps")
    ap.add_argument("--json-dir", default=None,
                    help="write BENCH_train.json into this directory")
    a = ap.parse_args()
    main(fast=a.fast, tiny=a.tiny, json_dir=a.json_dir)
