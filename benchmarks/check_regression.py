"""CI regression gate over the HARDWARE-INDEPENDENT structural bench
columns (ISSUE 5).

The perf story of this repo rests on structural contracts — stored
weight-bytes per decode step, HBM passes per optimizer leaf, decode
dispatch counts, prefill FLOPs saved by the prefix cache — that are
asserted inside the benchmark scripts but were never *diffed against the
committed baselines*, so a PR could quietly regress (say) the int4
weight-bytes ratio from 0.27x to 0.9x while every assertion still held.
This gate closes that hole: it loads freshly generated ``BENCH_*.json``
files (CI runs the ``--tiny`` smokes into ``/tmp``) and compares a
declared metric set against the committed baselines
(``benchmarks/baselines/BENCH_*.json``, falling back to the repo-root
records), failing the job on any regression.

Metric semantics:

* ``lower`` / ``higher`` — the good direction.  ``rel_tol`` absorbs the
  metric's legitimate run-to-run jitter: 0 for deterministic structural
  counts (bytes, passes, ratios, the stall bound); nonzero ONLY for
  replay-derived counts whose admission grouping depends on host wall
  time (decode launches, prefix-hit totals).
* ``true`` — a boolean contract (e.g. ``outputs_identical``) that must
  hold in the fresh run regardless of baseline.
* paths ending in ``#len`` gate the LENGTH of a list (the
  dense-materialization scan must stay empty).

Wall-clock columns are deliberately NOT gated — they are
machine-dependent and the JSONs record backend/dispatch precisely so
humans can compare like with like.  Config sub-dicts are required to
match exactly, so a tiny-vs-full or reshaped baseline fails loudly
instead of green-lighting an apples-to-oranges diff.

Usage::

    python -m benchmarks.check_regression --fresh-dir /tmp

Exit code 0 = no regressions (improvements are reported as baseline-
refresh suggestions); 1 = regression or missing/mismatched files.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
from typing import Optional

HERE = os.path.dirname(os.path.abspath(__file__))
REPO_ROOT = os.path.dirname(HERE)
BASELINE_DIR = os.path.join(HERE, "baselines")


@dataclasses.dataclass(frozen=True)
class Metric:
    path: str                 # dotted path into the bench JSON
    direction: str            # "lower" | "higher" | "true"
    rel_tol: float = 0.0      # allowed relative slack in the bad direction


# per-bench gated metrics; see the module docstring for why each
# tolerance is what it is
METRICS = {
    "serve": [
        Metric("structural.weight_bytes_per_decode_step.fp32_dense", "lower"),
        Metric("structural.weight_bytes_per_decode_step.bf16_dense", "lower"),
        Metric("structural.weight_bytes_per_decode_step.rtn_int8", "lower"),
        Metric("structural.weight_bytes_per_decode_step.rtn_int4", "lower"),
        Metric("structural.int4_vs_bf16", "lower"),
        Metric("structural.int8_vs_bf16", "lower"),
        Metric("structural.n_qtensor_leaves", "higher"),
        Metric("structural.dense_materializations_jaxpr#len", "lower"),
        Metric("structural.dense_materializations_hlo#len", "lower"),
        # KV twin of the weight contract (ISSUE 6): decode cache bytes
        # per step and no dense-cache rematerialization in the fused
        # decode-attention program — all deterministic, zero tolerance
        Metric("kv_structural.kv_bytes_per_decode_step.bf16_dense", "lower"),
        Metric("kv_structural.kv_bytes_per_decode_step.int8", "lower"),
        Metric("kv_structural.kv_bytes_per_decode_step.int4", "lower"),
        Metric("kv_structural.kv_int4_vs_bf16", "lower"),
        Metric("kv_structural.kv_int8_vs_bf16", "lower"),
        Metric("kv_structural.dense_materializations_jaxpr_int8#len",
               "lower"),
        Metric("kv_structural.dense_materializations_jaxpr_int4#len",
               "lower"),
        Metric("kv_structural.dense_materializations_hlo_int8#len", "lower"),
        Metric("kv_structural.dense_materializations_hlo_int4#len", "lower"),
        Metric("kv_structural.hlo_int_kv_params", "higher"),
        Metric("scheduler.outputs_identical", "true"),
        Metric("scheduler.max_ticks_per_request", "lower"),
        # replay admission grouping depends on host wall time: launch
        # totals jitter run to run (a slow runner serializes admissions,
        # up to sum(ceil(mnt/k)) ticks), so the slack is wide — the gate
        # is for catastrophic regressions (losing multi-step decode is
        # a ~10x jump to one launch per token)
        Metric("scheduler.continuous.decode_launches", "lower", 1.0),
        Metric("scheduler_chunked.outputs_identical", "true"),
        Metric("scheduler_chunked.max_ticks_per_request", "lower"),
        Metric("scheduler_chunked.continuous.prefill_stall_max_tokens",
               "lower"),
        Metric("scheduler_chunked.prefill_tokens_skipped", "higher", 0.5),
        Metric("scheduler_chunked.prefill_frac_saved", "higher", 0.5),
        # robustness chaos replay (ISSUE 7): every column below runs on
        # the deterministic virtual clock with a seeded fault plan and
        # no EOS-dependent termination, so the counts are machine-
        # independent — zero tolerance.  The invariant columns are the
        # acceptance bar itself: any violation, any non-terminal
        # request, or a bit-parity break fails CI outright.
        Metric("scheduler_robustness.invariant_violations", "lower"),
        Metric("scheduler_robustness.chaos_off_violations", "lower"),
        Metric("scheduler_robustness.chaos_all_terminal", "higher"),
        Metric("scheduler_robustness.chaos_off_bit_parity", "higher"),
        Metric("scheduler_robustness.chaos_deadline_hit_rate", "higher"),
        Metric("scheduler_robustness.preemptions", "higher"),
        Metric("scheduler_robustness.preempt_resume_splice_frac",
               "higher"),
        Metric("scheduler_robustness.overload_shed_on.deadline_hit_rate",
               "higher"),
        # paged KV block pool (ISSUE 10): every column comes from a
        # deterministic arrival-0 drain or a seeded virtual-clock chaos
        # replay — zero tolerance.  The sharing columns are the point of
        # the pool: ANY host splice transfer or a run with no shared
        # prefix blocks means the zero-copy path silently fell back to
        # row copies; reattach_exact is the quantized-KV resume gap
        # closure (preempted == unpreempted, zero recomputed tokens).
        Metric("scheduler_paged.outputs_identical", "true"),
        Metric("scheduler_paged.splice_host_transfers", "lower"),
        Metric("scheduler_paged.prefix_blocks_shared", "higher"),
        Metric("scheduler_paged.pool_bytes_per_context", "lower"),
        Metric("scheduler_paged.reattach_exact", "true"),
        Metric("scheduler_paged.reattach_recompute_tokens", "lower"),
        Metric("scheduler_paged.chaos_violations", "lower"),
        Metric("scheduler_paged.chaos_all_terminal", "true"),
    ],
    "train": [
        # training chaos replay (ISSUE 8): seeded fault plan + seeded
        # data + prefetch=0 make every counter deterministic on a fixed
        # backend — zero tolerance.  The invariant/parity columns are
        # the acceptance bar: any audit violation, a fault-free replay
        # that is not bit-identical to the plain run, or a chaos run
        # that does not finish with a finite loss fails CI outright.
        Metric("robustness.invariant_violations", "lower"),
        Metric("robustness.fault_free_violations", "lower"),
        Metric("robustness.fault_free_bit_parity", "true"),
        Metric("robustness.chaos_completed", "true"),
        Metric("robustness.final_loss_finite", "true"),
        # recovery-tier coverage: the plan must keep exercising skip,
        # rollback, resume and quarantine — a "green" chaos run that
        # stopped injecting faults is not a robustness proof
        Metric("robustness.skipped_steps", "higher"),
        Metric("robustness.rollbacks", "higher"),
        Metric("robustness.resumes", "higher"),
        Metric("robustness.crashes", "higher"),
        Metric("robustness.quarantined", "higher"),
        Metric("robustness.saves", "higher"),
        Metric("robustness.replayed_steps", "higher"),
        # distributed self-healing on the forced-device 2x4 mesh
        # (ISSUE 9): the coordinator runs on a virtual clock and the
        # host-fault plan is seeded, so every column is deterministic —
        # zero tolerance.  Parity/violation columns are the acceptance
        # bar (a fault-free mesh replay must stay bit-identical to the
        # plain 2x4 run); the tier counters prove the host-level faults
        # — peer kill, straggler, shard corruption, coordinated
        # rollback — keep actually firing and being healed.
        Metric("distributed.invariant_violations", "lower"),
        Metric("distributed.fault_free_violations", "lower"),
        Metric("distributed.fault_free_bit_parity", "true"),
        Metric("distributed.chaos_completed", "true"),
        Metric("distributed.final_loss_finite", "true"),
        Metric("distributed.host_kill_timeouts", "higher"),
        Metric("distributed.straggler_timeouts", "higher"),
        Metric("distributed.quarantined", "higher"),
        Metric("distributed.rollbacks", "higher"),
        Metric("distributed.divergence_checks", "higher"),
        Metric("distributed.data_windows_skipped", "higher"),
    ],
    "opt_step": [
        Metric("structural.fused_passes_per_leaf", "lower"),
        Metric("structural.unfused_passes_per_leaf", "lower"),
        Metric("structural.eliminated_passes_per_leaf", "higher"),
        Metric("structural.fused_kernel_contract.kernel_calls", "lower"),
        Metric("structural.fused_kernel_contract.kernel_reads", "lower"),
        Metric("structural.fused_kernel_contract.kernel_writes", "lower"),
        Metric("structural.fused_kernel_contract.extra_passes", "lower"),
    ],
}

# sub-trees that must be byte-equal between fresh and baseline so the
# numeric comparison is apples to apples
CONFIG_KEYS = {
    "serve": ["config", "scheduler_robustness.tick_s",
              "scheduler_robustness.est_tok_per_s",
              "scheduler_robustness.n_requests",
              "scheduler_paged.block_size",
              "scheduler_paged.n_requests"],
    "opt_step": ["structural.leaf_shape", "structural.n_leaves"],
    "train": ["config"],
}


def resolve(record: dict, path: str):
    want_len = path.endswith("#len")
    if want_len:
        path = path[:-len("#len")]
    node = record
    for part in path.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return len(node) if want_len else node


def check_bench(name: str, fresh: dict, base: dict) -> list:
    """Returns a list of failure strings (empty = green)."""
    failures = []
    for cpath in CONFIG_KEYS.get(name, []):
        f, b = resolve(fresh, cpath), resolve(base, cpath)
        if f != b:
            failures.append(
                f"{name}: {cpath} mismatch (fresh {f!r} vs baseline {b!r})"
                f" — regenerate the committed baseline with the SAME bench"
                f" configuration before gating")
    for m in METRICS.get(name, []):
        f = resolve(fresh, m.path)
        if m.direction == "true":
            if f is not True:
                failures.append(f"{name}: {m.path} must be true, got {f!r}")
            continue
        b = resolve(base, m.path)
        if f is None or b is None:
            failures.append(
                f"{name}: {m.path} missing "
                f"(fresh={f!r}, baseline={b!r})")
            continue
        f, b = float(f), float(b)
        slack = abs(b) * m.rel_tol
        if m.direction == "lower":
            regressed, improved = f > b + slack, f < b
        else:
            regressed, improved = f < b - slack, f > b
        if regressed:
            failures.append(
                f"{name}: {m.path} regressed ({m.direction} is better): "
                f"fresh {f:g} vs baseline {b:g} (rel_tol {m.rel_tol})")
        elif improved:
            print(f"  improvement: {name}: {m.path} {b:g} -> {f:g} "
                  f"(consider refreshing the committed baseline)")
    return failures


def find_baseline(name: str, baseline_dir: str) -> Optional[str]:
    for d in (baseline_dir, REPO_ROOT):
        p = os.path.join(d, f"BENCH_{name}.json")
        if os.path.exists(p):
            return p
    return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh-dir", required=True,
                    help="directory holding freshly generated BENCH_*.json")
    ap.add_argument("--baseline-dir", default=BASELINE_DIR,
                    help="committed baselines (falls back to repo root)")
    ap.add_argument("--bench", action="append", default=None,
                    help="gate only these bench names (default: all with "
                         "a declared metric set)")
    args = ap.parse_args(argv)

    names = args.bench or sorted(METRICS)
    failures = []
    for name in names:
        fresh_path = os.path.join(args.fresh_dir, f"BENCH_{name}.json")
        base_path = find_baseline(name, args.baseline_dir)
        if not os.path.exists(fresh_path):
            failures.append(f"{name}: fresh result {fresh_path} not found "
                            f"(did the bench smoke run?)")
            continue
        if base_path is None:
            failures.append(f"{name}: no committed baseline BENCH_"
                            f"{name}.json under {args.baseline_dir} or "
                            f"{REPO_ROOT}")
            continue
        with open(fresh_path) as fh:
            fresh = json.load(fh)
        with open(base_path) as fh:
            base = json.load(fh)
        print(f"checking {name}: {fresh_path} vs {base_path}")
        failures += check_bench(name, fresh, base)

    if failures:
        print("\nSTRUCTURAL REGRESSIONS:", file=sys.stderr)
        for f in failures:
            print(f"  FAIL {f}", file=sys.stderr)
        return 1
    print(f"ok: {len(names)} bench(es) within structural baselines")
    return 0


if __name__ == "__main__":
    sys.exit(main())
