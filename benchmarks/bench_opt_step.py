"""Fused vs unfused optimizer step: structural HBM pass count + wall clock.

Two measurements, both over the SAME update rule
(``clip -> lotion_decoupled -> adamw_core`` vs the collapsed
``fused_lotion_adamw_core``):

1. **Structural pass count** (the headline number, hardware-independent):
   * unfused — number of param-leaf-shaped buffer materializations in the
     optimized HLO ENTRY computation of the jitted update (every fusion
     root or standalone op that writes a full leaf-sized tensor is one
     HBM write pass, and implies reading its operands);
   * fused — the Pallas kernel's DMA contract read off the jaxpr: each
     ``pallas_call`` reads its leaf-sized operands once and writes its
     leaf-sized outputs once per grid sweep (exact on TPU, where
     BlockSpec tiles are fetched/flushed exactly once for a parallel
     grid).  Non-kernel leaf-sized materializations in the fused jaxpr
     (e.g. padding copies for unaligned leaves) are counted and reported
     so the fused number cannot silently cheat.

   The bench asserts the fusion structurally eliminates >= 5 of the ~8-11
   unfused passes (ISSUE 2 acceptance).

2. **Wall clock** of the full train step at 1/4/8 microbatches (p50/p95).
   NOTE: off-TPU the fused kernel runs in Pallas *interpret* mode, which
   is a correctness harness, not a performance path — expect the fused
   wall clock to LOSE on CPU.  The JSON records backend + interpret flag
   so perf trajectories only compare like with like.

Emits ``BENCH_opt_step.json`` (``--json-dir DIR``, shared
``write_bench_json`` format with ``benchmarks/run.py``); ``--tiny`` is
the CI smoke configuration (structural counts + 1-microbatch timing).
"""

from __future__ import annotations

import argparse
import re

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import QuantConfig, QuantPolicy
from repro.data import lm_batch, permutation_table
from repro.models.lm import LMConfig, lm_init
from repro.optim import adamw, constant
from repro.train import TrainConfig, init_state, make_optimizer, make_train_step

from .common import emit, time_percentiles, write_bench_json

LAM = 1e4
POLICY = QuantPolicy(min_size=256)

CFG = LMConfig(name="bench-opt-step", n_layers=4, d_model=128, n_heads=4,
               n_kv_heads=2, d_ff=256, vocab=256, head_dim=32,
               dtype=jnp.float32, remat=False)
CFG_TINY = LMConfig(name="bench-opt-step-tiny", n_layers=2, d_model=64,
                    n_heads=2, n_kv_heads=1, d_ff=128, vocab=64, head_dim=32,
                    dtype=jnp.float32, remat=False)

# a synthetic "params" tree with MXU-aligned leaves for the structural
# count (aligned so the fused path needs no padding copies — pads would
# show up in extra_passes and are a real cost on unaligned leaves)
BENCH_LEAF = (256, 512)


def _bench_tree(n_leaves: int = 4):
    params = {f"w{i}": jax.random.normal(jax.random.PRNGKey(i), BENCH_LEAF)
              for i in range(n_leaves)}
    grads = jax.tree.map(lambda x: x * 0.01, params)
    return params, grads


def _build_update(use_kernel: bool, params):
    qc = QuantConfig(method="lotion", fmt_name="int4", lam=LAM,
                     policy=POLICY, use_kernel=use_kernel)
    tc = TrainConfig(quant=qc, clip_norm=1.0)
    tx = make_optimizer(tc, adamw(constant(1e-3)))
    st = tx.init(params)

    def update(g, s, p):
        return tx.update(g, s, p, fisher=tx.fisher(s))

    return update, st


def count_unfused_passes(update, args, leaf_shape) -> int:
    """Leaf-shaped materializations in the optimized-HLO ENTRY block."""
    compiled = jax.jit(update).lower(*args).compile()
    hlo = compiled.as_text()
    m = re.search(r"ENTRY [^{]+\{(.*?)\n\}", hlo, re.S)
    assert m, "no ENTRY computation in HLO"
    shape_str = "f32[" + ",".join(str(d) for d in leaf_shape) + "]"
    skip = ("parameter", "tuple(", "get-tuple-element", "bitcast",
            "copy(", "constant")
    count = 0
    for line in m.group(1).splitlines():
        line = line.strip()
        mm = re.match(r"(?:ROOT )?\S+ = (\S+?)\[", line)
        if not mm or not line.split(" = ", 1)[1].startswith(shape_str):
            continue
        op = line.split(" = ", 1)[1][len(shape_str):].lstrip()
        if any(op.startswith(s) for s in skip):
            continue
        count += 1
    return count


def _walk_pallas(jaxpr, out):
    for eq in jaxpr.eqns:
        if eq.primitive.name == "pallas_call":
            out.append(eq)
        for v in eq.params.values():
            vs = v if isinstance(v, (list, tuple)) else (v,)
            for vv in vs:
                if hasattr(vv, "jaxpr"):
                    _walk_pallas(vv.jaxpr, out)
    return out


def count_fused_passes(update, args, leaf_size: int) -> dict:
    """Kernel DMA contract (reads/writes) + any non-kernel leaf-sized
    materializations that leaked outside the kernels."""
    jaxpr = jax.make_jaxpr(update)(*args)
    calls = _walk_pallas(jaxpr.jaxpr, [])
    assert calls, "fused update contains no pallas_call"
    reads = writes = 0
    for eq in calls:
        reads += sum(1 for v in eq.invars
                     if hasattr(v, "aval") and v.aval.size >= leaf_size)
        writes += sum(1 for v in eq.outvars if v.aval.size >= leaf_size)
    # leaf-sized tensors produced OUTSIDE kernels (pads etc.); reshapes,
    # converts and broadcasts are layout/virtual ops, not HBM round trips
    virtual = {"reshape", "broadcast_in_dim", "convert_element_type",
               "squeeze", "pallas_call"}
    extra = sum(1 for eq in jaxpr.jaxpr.eqns
                if eq.primitive.name not in virtual
                and any(v.aval.size >= leaf_size for v in eq.outvars))
    return {"kernel_calls": len(calls), "kernel_reads": reads,
            "kernel_writes": writes, "extra_passes": extra}


def structural(n_leaves: int = 4) -> dict:
    params, grads = _bench_tree(n_leaves)
    leaf_size = int(np.prod(BENCH_LEAF))

    upd_u, st_u = _build_update(False, params)
    unfused_total = count_unfused_passes(upd_u, (grads, st_u, params),
                                         BENCH_LEAF)
    unfused_per_leaf = unfused_total / n_leaves

    upd_f, st_f = _build_update(True, params)
    fused = count_fused_passes(upd_f, (grads, st_f, params), leaf_size)
    fused_per_leaf = (fused["kernel_writes"] + fused["extra_passes"]
                      ) / n_leaves

    eliminated = unfused_per_leaf - fused_per_leaf
    rec = {
        "leaf_shape": list(BENCH_LEAF), "n_leaves": n_leaves,
        "unfused_passes_per_leaf": unfused_per_leaf,
        "fused_passes_per_leaf": fused_per_leaf,
        "fused_kernel_contract": fused,
        "eliminated_passes_per_leaf": eliminated,
    }
    # ISSUE 2 acceptance: the fusion must structurally remove >= 5 of the
    # unfused chain's per-step elementwise HBM passes
    assert eliminated >= 5, rec
    return rec


def wallclock(cfg: LMConfig, micro, n_iter: int = 10) -> dict:
    perm = permutation_table(0, cfg.vocab)
    batch_size, seq = 16, 64
    out = {}
    for n_micro in micro:
        row = {}
        for label, use_kernel in (("unfused", False), ("fused", True)):
            qc = QuantConfig(method="lotion", fmt_name="int4", lam=LAM,
                             policy=POLICY, use_kernel=use_kernel)
            tc = TrainConfig(quant=qc, clip_norm=1.0, n_microbatches=n_micro)
            tx = make_optimizer(tc, adamw(constant(1e-3)))
            params = lm_init(jax.random.PRNGKey(0), cfg)
            state = init_state(params, tx)
            step = jax.jit(make_train_step(cfg, tc, tx))
            b = lm_batch(0, 0, batch_size, seq, cfg.vocab, perm)
            p50, p95 = time_percentiles(step, state, b, n_iter=n_iter)
            row[label] = {"p50_us": p50, "p95_us": p95}
            emit(f"opt_step_{label}_mb{n_micro}", p50, f"p95={p95:.1f}us")
        row["fused_speedup_p50"] = (row["unfused"]["p50_us"]
                                    / row["fused"]["p50_us"])
        out[f"mb{n_micro}"] = row
    return out


def main(fast: bool = False, tiny: bool = False, json_dir: str = None):
    micro = (1,) if tiny else ((1, 4) if fast else (1, 4, 8))
    cfg = CFG_TINY if tiny else CFG
    rec = {
        "bench": "opt_step",
        "backend": jax.default_backend(),
        "pallas_interpret": jax.default_backend() != "tpu",
        "structural": structural(),
        "wallclock_microbatch": wallclock(cfg, micro,
                                          n_iter=3 if tiny else 10),
        "note": ("structural pass counts are hardware-independent; "
                 "wall-clock off-TPU runs the kernel in interpret mode "
                 "and only the unfused numbers are meaningful there"),
    }
    s = rec["structural"]
    emit("opt_step_passes_unfused", 0.0,
         f"per_leaf={s['unfused_passes_per_leaf']:.1f}")
    emit("opt_step_passes_fused", 0.0,
         f"per_leaf={s['fused_passes_per_leaf']:.1f}")
    emit("opt_step_passes_eliminated", 0.0,
         f"per_leaf={s['eliminated_passes_per_leaf']:.1f}")
    if json_dir is not None:
        print(f"wrote {write_bench_json('opt_step', rec, json_dir)}")
    return rec


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: structural counts + mb=1 timing")
    ap.add_argument("--json-dir", default=None,
                    help="write BENCH_opt_step.json into this directory")
    a = ap.parse_args()
    main(fast=a.fast, tiny=a.tiny, json_dir=a.json_dir)
