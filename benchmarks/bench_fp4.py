"""Paper Figure 5 / Figure 12 (scaled): FP4 (e2m1) quantized validation
loss for PTQ / QAT / LOTION on the scaled LM."""

from __future__ import annotations

from .bench_lm_quant import train_one
from .common import emit


def main():
    results = {}
    for method, lam in (("ptq", 0.0), ("qat", 0.0), ("lotion", 1000.0)):
        fp32, rtn, rr = train_one(method, "fp4", lam)
        results[method] = min(rtn, rr)
        emit(f"fig5_lm_fp4_{method}", 0.0,
             f"fp32={fp32:.4f};rtn={rtn:.4f};rr={rr:.4f}")
    emit("fig5_lotion_competitive_fp4", 0.0,
         f"holds={results['lotion'] <= results['ptq'] + 0.02}")


if __name__ == "__main__":
    main()
