"""Step-time: loss-side vs decoupled LOTION penalty at 1/4/8 microbatches.

The loss-side placement re-traverses the penalty (forward + backward)
once per microbatch inside the ``lax.scan``; the decoupled placement
applies the closed-form gradient exactly once per step, after the scan.
Each cell emits the measured step time plus ``penalty_evals_per_step``,
derived structurally from the jaxpr: the penalty math (the ``floor`` of
``fmt.neighbors``) appears in the microbatch scan body for loss placement
only — the bench asserts the decoupled body is penalty-free.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import QuantConfig, QuantPolicy
from repro.data import lm_batch, permutation_table
from repro.models.lm import LMConfig, lm_init
from repro.optim import adamw, cosine_with_warmup
from repro.train import TrainConfig, init_state, make_optimizer, make_train_step
from .common import emit, time_call

CFG = LMConfig(name="bench-placement", n_layers=4, d_model=128, n_heads=4,
               n_kv_heads=2, d_ff=256, vocab=256, head_dim=32,
               dtype=jnp.float32, remat=False)
BATCH, SEQ = 16, 64
LAM = 1e4
POLICY = QuantPolicy(min_size=256)


def _penalty_in_scan(step, state, batch) -> bool:
    """True iff the penalty math runs inside the microbatch scan body.

    Marker: ``floor`` only enters the step through ``fmt.neighbors`` (the
    quantization-cell bracket) — the LM forward/backward and CE have none.
    """
    jaxpr = jax.make_jaxpr(step)(state, batch)
    scans = [eq for eq in jaxpr.eqns if eq.primitive.name == "scan"]
    return any("floor" in str(eq.params["jaxpr"]) for eq in scans)


def bench_one(placement: str, n_micro: int):
    qcfg = QuantConfig(method="lotion", fmt_name="int4", lam=LAM,
                       policy=POLICY, penalty_placement=placement)
    tcfg = TrainConfig(quant=qcfg, n_microbatches=n_micro)
    opt = make_optimizer(tcfg, adamw(cosine_with_warmup(3e-3, 20, 1000),
                                     weight_decay=0.0))
    params = lm_init(jax.random.PRNGKey(0), CFG)
    state = init_state(params, opt)
    step = make_train_step(CFG, tcfg, opt)
    perm = permutation_table(0, CFG.vocab)
    batch = lm_batch(0, 0, BATCH, SEQ, CFG.vocab, perm)

    if n_micro > 1:
        in_scan = _penalty_in_scan(step, state, batch)
        evals = n_micro if in_scan else 1
    else:
        in_scan = False
        evals = 1
    if placement == "decoupled":
        assert not in_scan, "decoupled penalty leaked into the scan body"
        assert evals == 1

    fn = jax.jit(step)
    us = time_call(fn, state, batch, n_warmup=2, n_iter=10)
    return us, evals


def main(fast: bool = False):
    micro = (1, 4) if fast else (1, 4, 8)
    times = {}
    for placement in ("loss", "decoupled"):
        for n in micro:
            us, evals = bench_one(placement, n)
            times[(placement, n)] = us
            emit(f"penalty_placement_{placement}_mb{n}", us,
                 f"penalty_evals_per_step={evals}")
    for n in micro:
        lo, de = times[("loss", n)], times[("decoupled", n)]
        emit(f"penalty_placement_speedup_mb{n}", de,
             f"decoupled_vs_loss={lo / de:.3f}x")


if __name__ == "__main__":
    main()
