"""Kernel microbenchmarks: fused Pallas paths vs stock-jnp references.

On this CPU container the Pallas kernels run in interpret mode, so the
*wall-times are not TPU numbers* — the derived column carries the
analytic HBM-traffic ratio (the quantity the fusion actually buys on
TPU), and wall time is reported for the stock-jnp path only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import INT4, lotion_penalty_and_grad, quantize
from .common import emit, time_call

SHAPE = (1024, 1024)


def main():
    w = jax.random.normal(jax.random.PRNGKey(0), SHAPE)
    f = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), SHAPE))

    # stock path HBM traffic: absmax read + scale write + round read/write
    # + dequant read/write + penalty read(w,f)/write(grad)  (~7 passes)
    # fused: read(w,f) + write(grad) (+ scalar)              (~3 passes)
    n_bytes = w.size * 4

    jr = jax.jit(lambda x: quantize.cast_rtn(x, INT4, 256))
    us = time_call(jr, w)
    emit("kernel_quant_rtn_stock_jnp", us,
         f"hbm_passes=4;bytes={4*n_bytes}")
    emit("kernel_quant_rtn_fused_pallas", 0.0,
         f"hbm_passes=2;bytes={2*n_bytes};traffic_ratio=0.50;interpret_only=1")

    jp = jax.jit(lambda x, ff: lotion_penalty_and_grad(x, ff, INT4, 256))
    us = time_call(jp, w, f)
    emit("kernel_lotion_reg_stock_jnp", us,
         f"hbm_passes=5;bytes={5*n_bytes}")
    emit("kernel_lotion_reg_fused_pallas", 0.0,
         f"hbm_passes=3;bytes={3*n_bytes};traffic_ratio=0.60;interpret_only=1")

    # wq_matmul: weight bytes read per matmul
    m, k, n = 8, 1024, 1024
    x = jax.random.normal(jax.random.PRNGKey(2), (m, k), jnp.float32)
    wt = jax.random.normal(jax.random.PRNGKey(3), (k, n), jnp.float32)
    jm = jax.jit(lambda a, b: a @ b)
    us = time_call(jm, x, wt.astype(jnp.float32))
    emit("kernel_matmul_bf16_weights", us,
         f"weight_bytes={k*n*2}")
    emit("kernel_wq_matmul_int4_pallas", 0.0,
         f"weight_bytes={k*n//2 + (k//128)*n*4};traffic_ratio="
         f"{(k*n//2 + (k//128)*n*4)/(k*n*2):.3f};interpret_only=1")


if __name__ == "__main__":
    main()
