"""Paper Figure 2 / Figure 7: INT4 quantized validation loss on the
linear-regression quadratic with power-law spectrum (lambda_i ~ i^-1.1).

Setup mirrors §4.1: SGD on sampled Gaussian data, cosine LR, small LR
grid per method; quantized eval under RTN and exact-expected RR
(E[L(RR(w))] = L(w) + 1/2 sum lambda_i var_i — Eq. 1, exact for the
quadratic).  d is scaled 12000 -> 2000 for the CPU container (structure
preserved; see DESIGN.md §5).

Paper claims checked:
  * LOTION best on the RR/smoothed metric (its optimization target);
  * QAT worst by a wide margin (paper: 0.79 vs 0.14-0.33);
  * RAT ~ PTQ for quadratics (Lemma 3: RR gradients are unbiased).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import INT4, cast_rr, cast_rtn, lotion_penalty, rr_variance
from repro.models.linear import linreg_population_loss, power_law_spectrum
from .common import emit, time_call

D = 2000
STEPS = 8000
BSZ = 32


@functools.partial(jax.jit, static_argnames=("method",))
def _train(w_star, spec, lr0, method: str, lam: float = 0.5, seed: int = 0):
    sq = jnp.sqrt(spec)

    def lr_at(t):
        return lr0 * (0.55 + 0.45 * jnp.cos(jnp.pi * t / STEPS))

    def sgd_grad(u, t):
        x = jax.random.normal(
            jax.random.fold_in(jax.random.PRNGKey(seed + 1), t), (BSZ, D)) * sq
        return x.T @ (x @ (u - w_star)) / BSZ

    def step(w, t):
        if method == "lotion":
            g = sgd_grad(w, t) + lam * jax.grad(
                lambda u: lotion_penalty(u, spec, INT4, -1))(w)
        elif method == "qat":
            g = sgd_grad(cast_rtn(w, INT4), t)          # STE: grad at RTN(w)
        elif method == "rat":
            key = jax.random.fold_in(jax.random.PRNGKey(seed + 2), t)
            g = sgd_grad(cast_rr(w, INT4, key), t)      # STE: grad at RR(w)
        else:  # ptq
            g = sgd_grad(w, t)
        return w - lr_at(t) * g, None

    w, _ = jax.lax.scan(step, jnp.zeros((D,)), jnp.arange(STEPS))
    return w


def _eval(w, w_star, spec):
    rtn = float(linreg_population_loss(cast_rtn(w, INT4), w_star, spec))
    # exact E over RR (Eq. 1)
    err = float(linreg_population_loss(w, w_star, spec)
                + 0.5 * jnp.sum(spec * rr_variance(w, INT4, -1)))
    return rtn, err


def run():
    spec = power_law_spectrum(D)
    w_star = jax.random.normal(jax.random.PRNGKey(7), (D,))
    results = {}
    for method in ("ptq", "qat", "rat", "lotion"):
        best = None
        for lr in (0.6, 1.2):
            w = _train(w_star, spec, lr, method)
            rtn, err = _eval(w, w_star, spec)
            fp32 = float(linreg_population_loss(w, w_star, spec))
            if best is None or min(rtn, err) < min(best[0], best[1]):
                best = (rtn, err, fp32, lr)
        results[method] = best
    return results


def main():
    spec = power_law_spectrum(D)
    w_star = jax.random.normal(jax.random.PRNGKey(7), (D,))
    us = time_call(lambda: _train(w_star, spec, 0.6, "lotion"), n_iter=1)
    res = run()
    for m, (rtn, err, fp32, lr) in res.items():
        emit(f"fig2_quadratic_int4_{m}", us,
             f"rtn={rtn:.5f};E_rr={err:.5f};fp32={fp32:.5f};lr={lr}")
    emit("fig2_lotion_best_on_rr", 0.0,
         f"holds={res['lotion'][1] < min(res['ptq'][1], res['qat'][1], res['rat'][1])}")
    emit("fig2_qat_worst", 0.0,
         f"holds={min(res['qat'][:2]) > max(min(res[m][:2]) for m in ('ptq', 'rat', 'lotion'))}")
    emit("fig2_lemma3_rat_matches_ptq", 0.0,
         f"holds={abs(res['rat'][1] - res['ptq'][1]) < 0.35 * res['ptq'][1]}")


if __name__ == "__main__":
    main()
