"""Paper Figure 3 / Figure 8: final INT4-quantized loss vs hidden width k
for the two-layer linear network f(x) = W2 W1 x / k.

Methods: LOTION (exact Gauss-Newton diag, closed form for this model),
QAT, PTQ, and the paper's GT construction (W2 = 1, rows of W1 = w*) —
whose rounded loss goes to 0 as k grows (Lemma 4).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import INT4, cast_rr, cast_rtn, lotion_penalty
from repro.models.linear import (power_law_spectrum, twolayer_ground_truth,
                                 twolayer_init, twolayer_population_loss)
from .common import emit, time_call

D = 2000
STEPS = 300
KS = (16, 64, 256)


def _gn_diag(params, spec, k):
    """Exact Gauss-Newton diagonal for the deep-linear model:
    v = W2 W1 / k;  g_ii(W1[i,j]) = lambda_j (W2[0,i]/k)^2;
    g_ii(W2[0,i]) = sum_j lambda_j (W1[i,j]/k)^2."""
    w1, w2 = params["w1"], params["w2"]
    return {
        "w1": spec[None, :] * (w2[0][:, None] / k) ** 2,
        "w2": (spec[None, :] * (w1 / k) ** 2).sum(-1, keepdims=True).T,
    }


@functools.partial(jax.jit, static_argnames=("k", "method"))
def _train(w_star, spec, k: int, lr0, method: str, lam: float = 1.0):
    def loss(p):
        return twolayer_population_loss(p, w_star, spec, k)

    def lr_at(t):
        return lr0 * (0.55 + 0.45 * jnp.cos(jnp.pi * t / STEPS))

    def cast_tree(p, key=None):
        if key is None:
            return jax.tree.map(lambda x: cast_rtn(x, INT4), p)
        ks = jax.random.split(key, 2)
        return {"w1": cast_rr(p["w1"], INT4, ks[0]),
                "w2": cast_rr(p["w2"], INT4, ks[1])}

    def step(p, t):
        if method == "qat":
            def obj(u):
                q = jax.tree.map(
                    lambda x: cast_rtn(jax.lax.stop_gradient(x), INT4)
                    + (x - jax.lax.stop_gradient(x)), u)
                return loss(q)
            g = jax.grad(obj)(p)
        elif method == "lotion":
            def obj(u):
                gn = _gn_diag(u, spec, k)
                pen = sum(lotion_penalty(u[n], jax.lax.stop_gradient(gn[n]),
                                         INT4, -1) for n in ("w1", "w2"))
                return loss(u) + lam * pen
            g = jax.grad(obj)(p)
        else:
            g = jax.grad(loss)(p)
        return jax.tree.map(lambda x, gg: x - lr_at(t) * gg, p, g), None

    p0 = twolayer_init(jax.random.PRNGKey(0), D, k)
    p, _ = jax.lax.scan(step, p0, jnp.arange(STEPS))
    return p


def _quant_loss(p, w_star, spec, k, key):
    rtn = jax.tree.map(lambda x: cast_rtn(x, INT4), p)
    ks = jax.random.split(key, 2)
    rr = {"w1": cast_rr(p["w1"], INT4, ks[0]),
          "w2": cast_rr(p["w2"], INT4, ks[1])}
    return (float(twolayer_population_loss(rtn, w_star, spec, k)),
            float(twolayer_population_loss(rr, w_star, spec, k)))


def main():
    spec = power_law_spectrum(D)
    w_star = jax.random.normal(jax.random.PRNGKey(7), (D,)) * 0.5
    key = jax.random.PRNGKey(3)
    us = time_call(lambda: _train(w_star, spec, KS[0], 0.3, "lotion"))
    gt_prev = None
    for k in KS:
        row = {}
        for method in ("ptq", "qat", "lotion"):
            best = None
            for lr in (0.1, 0.3):
                p = _train(w_star, spec, k, lr, method)
                rtn, rr = _quant_loss(p, w_star, spec, k, key)
                cand = min(rtn, rr)
                best = cand if best is None or cand < best else best
            row[method] = best
        gt = twolayer_ground_truth(w_star, k)
        rtn, rr = _quant_loss(gt, w_star, spec, k, key)
        row["gt"] = min(rtn, rr)
        emit(f"fig3_twolayer_k{k}", us,
             ";".join(f"{m}={v:.5f}" for m, v in row.items()))
        # Lemma 4: GT rounded loss decreases with k
        if gt_prev is not None:
            emit(f"fig3_lemma4_gt_decreasing_k{k}", 0.0,
                 f"holds={row['gt'] <= gt_prev * 1.5}")
        gt_prev = row["gt"]


if __name__ == "__main__":
    main()
