"""Root pytest conftest: honor REPRO_FORCE_DEVICES before jax imports.

Multi-device tests (tests/test_distributed_robustness.py's @needs8
group) need forced host devices, which XLA only reads at backend init —
i.e. before ANY test module imports jax.  Setting the flag here, at
collection time, makes

    REPRO_FORCE_DEVICES=8 python -m pytest ...

work without every test file repeating the env dance the launcher does.
Unset, nothing changes (single default device; the @needs8 tests skip).
"""

import os

if os.environ.get("REPRO_FORCE_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count="
        f"{os.environ['REPRO_FORCE_DEVICES']} "
        + os.environ.get("XLA_FLAGS", ""))
